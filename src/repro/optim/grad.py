"""Gradient utilities: accumulation and compressed cross-replica reduction.

Distributed-optimization tricks (system deliverable):
  * ``accumulate_grads`` — microbatch gradient accumulation via lax.scan
    (keeps HLO size constant in the number of microbatches).
  * ``compress``/``decompress`` — bf16 gradient compression with fp32
    error-feedback residual, halving the reduce-scatter volume on the data
    axis; the residual keeps the optimizer trajectory unbiased over time.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["accumulate_grads", "compress_bf16", "decompress_bf16"]

Params = Any


def accumulate_grads(
    loss_fn: Callable[[Params, dict], jax.Array],
    params: Params,
    micro_batches: dict[str, jax.Array],  # leaves [n_micro, ...]
) -> tuple[jax.Array, Params]:
    """Mean loss and grads over the leading microbatch axis via lax.scan."""
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, mb):
        acc_loss, acc_grads = carry
        loss, grads = grad_fn(params, mb)
        acc_grads = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc_grads, grads)
        return (acc_loss + loss, acc_grads), None

    n = jax.tree.leaves(micro_batches)[0].shape[0]
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), micro_batches)
    inv = 1.0 / n
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def compress_bf16(
    grads: Params, residual: Params | None = None
) -> tuple[Params, Params]:
    """bf16 compression with error feedback. Returns (compressed, new_residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def comp(g, r):
        corrected = g.astype(jnp.float32) + r
        c = corrected.astype(jnp.bfloat16)
        return c, corrected - c.astype(jnp.float32)

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    cs, rs = zip(*(comp(g, r) for g, r in zip(flat_g, flat_r)))
    return jax.tree.unflatten(td, list(cs)), jax.tree.unflatten(td, list(rs))


def decompress_bf16(grads: Params) -> Params:
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
